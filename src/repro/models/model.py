"""LM wrapper: init / train loss / prefill / decode for every architecture.

Batch dicts by family:
  LM (dense/moe/ssm/hybrid): {"tokens": (B, S) int32, "targets": (B, S)}
  vlm:   + {"patches": (B, frontend_len, frontend_dim)}  (stub embeddings)
  audio: {"frames": (B, S, frontend_dim), "targets": (B, S)}  (encoder)
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from .layers import dense_apply, dense_init, embed_apply, embed_init, rmsnorm_apply, rmsnorm_init
from .transformer import stack_apply, stack_cache_init, stack_init

Params = dict


def model_init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 5)
    p, s = {}, {}
    if cfg.family == "audio":
        p["frontend"], s["frontend"] = dense_init(
            ks[0], cfg.frontend_dim, cfg.d_model, ("frontend", "embed"))
    else:
        p["embed"], s["embed"] = embed_init(ks[0], cfg.vocab_size, cfg.d_model)
        if cfg.family == "vlm":
            p["patch_proj"], s["patch_proj"] = dense_init(
                ks[1], cfg.frontend_dim, cfg.d_model, ("frontend", "embed"))
    p["stack"], s["stack"] = stack_init(ks[2], cfg)
    p["final_norm"], s["final_norm"] = rmsnorm_init(cfg.d_model)
    if not cfg.tie_embeddings:
        p["lm_head"], s["lm_head"] = dense_init(
            ks[3], cfg.d_model, cfg.vocab_size, ("embed", "vocab"))
    return p, s


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


def _embed_inputs(p, batch, cfg: ModelConfig):
    dt = _dtype(cfg)
    if cfg.family == "audio":
        return dense_apply(p["frontend"], batch["frames"].astype(dt), "btf,fd->btd")
    x = embed_apply(p["embed"], batch["tokens"], dt)
    if cfg.family == "vlm" and "patches" in batch:
        px = dense_apply(p["patch_proj"], batch["patches"].astype(dt), "btf,fd->btd")
        x = jnp.concatenate([px, x], axis=1)  # patches prefix the text
    return x


def _logits(p, x, cfg: ModelConfig):
    x = rmsnorm_apply(p["final_norm"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        return jnp.einsum("btd,vd->btv", x, p["embed"]["emb"].astype(x.dtype))
    return dense_apply(p["lm_head"], x, "btd,dv->btv")


def _cast_params(p, cfg: ModelConfig):
    """Cast the whole tree to compute dtype ONCE, before the layer scan.

    With fp32 masters and per-layer casts the partitioner all-gathers fp32
    then converts (2x FSDP gather traffic); casting first makes every
    gather bf16 (§Perf iter 3)."""
    dt = _dtype(cfg)

    def leaf(a):
        return a.astype(dt) if a.dtype == jnp.float32 else a

    return jax.tree.map(leaf, p)


def forward(p, batch, cfg: ModelConfig, *, par=None, remat: str = "none"):
    """Full-sequence forward -> logits (B, S_out, V)."""
    p = _cast_params(p, cfg)
    x = _embed_inputs(p, batch, cfg)
    x, _ = stack_apply(p["stack"], x, cfg, mode="train", par=par, remat=remat)
    if cfg.family == "vlm":
        x = x[:, cfg.frontend_len :]  # loss only over text positions
    return _logits(p, x, cfg)


def loss_fn(p, batch, cfg: ModelConfig, *, par=None, remat: str = "none"):
    """Mean next-token (LM) or per-frame (encoder) cross entropy, fp32."""
    logits = forward(p, batch, cfg, par=par, remat=remat).astype(jnp.float32)
    targets = batch["targets"]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = logz - gold
    mask = batch.get("mask")
    if mask is not None:
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    assert not cfg.is_encoder_only, "encoder-only archs have no decode step"
    dt = jnp.dtype(cfg.cache_dtype) if cfg.cache_dtype else _dtype(cfg)
    return stack_cache_init(cfg, batch, max_len, dt)


def _map_layer_caches(tree, fn):
    """Apply ``fn`` to every per-layer attention/MLA cache dict (a dict
    with a ``pos`` leaf) in a cache pytree, leaving other nodes alone."""
    if isinstance(tree, dict) and "pos" in tree:
        return fn(tree)
    if isinstance(tree, dict):
        return {k: _map_layer_caches(v, fn) for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        return type(tree)(_map_layer_caches(v, fn) for v in tree)
    return tree


def cache_with_lengths(cache, lengths):
    """Replace every layer cache's prefill ``pos`` with per-row valid
    lengths (B,), so a right-padded ragged prefill leaves each row's
    decode write index at its own prompt length instead of the padded
    one. Stacked (scanned) layer caches carry a leading layer axis on
    ``pos``; the vector broadcasts across it."""
    lengths = jnp.asarray(lengths, jnp.int32)

    def fix(lc):
        pos = lc["pos"]
        if pos.ndim == 0:
            new = lengths
        else:  # stacked: (L,) scalar-per-layer -> (L, B)
            new = jnp.broadcast_to(lengths, pos.shape + lengths.shape)
        return {**lc, "pos": new}

    return _map_layer_caches(cache, fix)


def prefill(p, batch, cache, cfg: ModelConfig, *, par=None, lengths=None):
    """Run the prompt through the stack, filling the cache.

    Returns (last-position logits (B, V), cache). With ``lengths`` (B,)
    the prompt batch is right-padded: logits are gathered per row at
    ``lengths - 1`` (causal masking makes every valid position's
    activations bit-identical to the unpadded run) and the cache ``pos``
    leaves become the per-row lengths vector."""
    p = _cast_params(p, cfg)
    x = _embed_inputs(p, batch, cfg)
    x, cache = stack_apply(p["stack"], x, cfg, mode="prefill", caches=cache, par=par)
    if lengths is None:
        return _logits(p, x[:, -1:], cfg)[:, 0], cache
    assert cfg.family not in ("vlm", "audio"), \
        "ragged prefill covers token-only prompts"
    lengths = jnp.asarray(lengths, jnp.int32)
    last = jnp.take_along_axis(x, (lengths - 1)[:, None, None], axis=1)
    return _logits(p, last, cfg)[:, 0], cache_with_lengths(cache, lengths)


def decode_step(p, tokens, cache, cfg: ModelConfig, *, positions=None, par=None):
    """One decode step. tokens: (B, 1) -> (logits (B, V), cache)."""
    dt = _dtype(cfg)
    p = _cast_params(p, cfg)
    x = embed_apply(p["embed"], tokens, dt)
    x, cache = stack_apply(p["stack"], x, cfg, mode="decode", caches=cache,
                           positions=positions, par=par)
    return _logits(p, x, cfg)[:, 0], cache
