"""repro.models — model zoo for the assigned architectures."""
from .model import cache_with_lengths, decode_step, forward, init_cache, loss_fn, model_init, prefill  # noqa: F401
