"""repro.models — model zoo for the assigned architectures."""
from .model import decode_step, forward, init_cache, loss_fn, model_init, prefill  # noqa: F401
