"""Mamba2 (State Space Duality) mixer — chunked dual form + recurrent decode.

Faithful to the SSD formulation (arXiv:2405.21060, n_groups=1):
  h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t ;  y_t = C_t . h_t + D x_t
Training/prefill uses the chunked algorithm: intra-chunk attention-like
matmuls (MXU-heavy) + an inter-chunk state scan of length L/chunk. Decode
keeps (conv_state, ssm_state) and costs O(1) per token — this is why
mamba2/zamba2 are the long_500k architectures.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from .layers import dense_init, rmsnorm_apply

Params = dict


def ssm_dims(cfg: ModelConfig):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    nh = d_in // s.head_dim
    conv_dim = d_in + 2 * s.d_state
    return d_in, nh, conv_dim


def ssm_init(key, cfg: ModelConfig):
    s = cfg.ssm
    d = cfg.d_model
    d_in, nh, conv_dim = ssm_dims(cfg)
    ks = jax.random.split(key, 4)
    p, sp = {}, {}
    in_dim = 2 * d_in + 2 * s.d_state + nh  # z, x, B, C, dt
    p["in_proj"], sp["in_proj"] = dense_init(ks[0], d, in_dim, ("embed", "mlp"))
    p["conv_w"] = {"w": jax.random.normal(ks[1], (s.d_conv, conv_dim), jnp.float32) * 0.2}
    sp["conv_w"] = {"w": (None, "mlp")}
    p["conv_b"] = {"b": jnp.zeros((conv_dim,), jnp.float32)}
    sp["conv_b"] = {"b": ("mlp",)}
    p["A_log"] = {"a": jnp.log(jnp.linspace(1.0, 16.0, nh).astype(jnp.float32))}
    sp["A_log"] = {"a": ("heads",)}
    p["D"] = {"d": jnp.ones((nh,), jnp.float32)}
    sp["D"] = {"d": ("heads",)}
    p["dt_bias"] = {"b": jnp.zeros((nh,), jnp.float32)}
    sp["dt_bias"] = {"b": ("heads",)}
    p["norm"] = {"scale": jnp.ones((d_in,), jnp.float32)}
    sp["norm"] = {"scale": ("mlp",)}
    p["out_proj"], sp["out_proj"] = dense_init(ks[2], d_in, d, ("mlp", "embed"))
    return p, sp


def _causal_conv(u: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv along S. u: (B, S, C); w: (K, C)."""
    k = w.shape[0]
    pad = jnp.pad(u, [(0, 0), (k - 1, 0), (0, 0)])
    acc = jnp.zeros_like(u, dtype=jnp.float32)
    s = u.shape[1]
    for i in range(k):
        acc = acc + pad[:, i : i + s, :].astype(jnp.float32) * w[i].astype(jnp.float32)
    return jax.nn.silu(acc + b.astype(jnp.float32)).astype(u.dtype)


def _split_zxbcdt(zxbcdt, cfg: ModelConfig):
    s = cfg.ssm
    d_in, nh, _ = ssm_dims(cfg)
    z = zxbcdt[..., :d_in]
    xbc = zxbcdt[..., d_in : 2 * d_in + 2 * s.d_state]
    dt = zxbcdt[..., 2 * d_in + 2 * s.d_state :]
    return z, xbc, dt


def ssd_chunked(x, dt, a_coef, bmat, cmat, chunk: int, unroll: bool = False):
    """SSD forward. x: (B,L,H,P); dt: (B,L,H); a_coef: (H,) negative;
    bmat/cmat: (B,L,N). Returns y: (B,L,H,P), final state (B,H,P,N)."""
    b, l, h, p_ = x.shape
    n = bmat.shape[-1]
    q = min(chunk, l)
    assert l % q == 0
    nc = l // q
    xc = jnp.moveaxis(x.reshape(b, nc, q, h, p_), 1, 0)
    dtc = jnp.moveaxis(dt.reshape(b, nc, q, h).astype(jnp.float32), 1, 0)
    bc = jnp.moveaxis(bmat.reshape(b, nc, q, n), 1, 0)
    cc = jnp.moveaxis(cmat.reshape(b, nc, q, n), 1, 0)
    i_idx = jnp.arange(q)
    tri = i_idx[:, None] >= i_idx[None, :]

    def step(hstate, inp):
        # all per-chunk work lives inside the scan: O(q^2 h) transient only
        x_c, dt_c, b_c, c_c = inp  # (b,q,h,p) (b,q,h) (b,q,n) (b,q,n)
        da = dt_c * a_coef[None, None, :]  # (b,q,h)
        cum = jnp.cumsum(da, axis=1)
        # mask the exponent BEFORE exp: the upper triangle has positive
        # (cum_i - cum_j) that overflows to inf, and inf * 0 = NaN
        expo = cum[:, :, None, :] - cum[:, None, :, :]
        decay = jnp.exp(jnp.where(tri[None, :, :, None], expo, -jnp.inf))
        scores = jnp.einsum("bin,bjn->bij", c_c.astype(jnp.float32),
                            b_c.astype(jnp.float32))
        w = scores[..., None] * decay * dt_c[:, None, :, :]  # (b,i,j,h)
        y_intra = jnp.einsum("bijh,bjhp->bihp", w, x_c.astype(jnp.float32))
        # inter-chunk: contribution of the carried state
        y_inter = jnp.einsum("bin,bhpn->bihp", c_c.astype(jnp.float32), hstate)
        y_inter = y_inter * jnp.exp(cum)[..., None]
        # update carried state
        end_decay = jnp.exp(cum[:, -1:, :] - cum)  # (b,q,h)
        sc = jnp.einsum("bjn,bjh,bjhp->bhpn", b_c.astype(jnp.float32),
                        end_decay * dt_c, x_c.astype(jnp.float32))
        hstate = hstate * jnp.exp(cum[:, -1, :])[:, :, None, None] + sc
        return hstate, (y_intra + y_inter).astype(x.dtype)

    h0 = jnp.zeros((b, h, p_, n), jnp.float32)
    if unroll:  # analysis variants only (cost_analysis counts scans once)
        ys = []
        hfin = h0
        for i in range(nc):
            hfin, yi = step(hfin, jax.tree.map(lambda a, i=i: a[i], (xc, dtc, bc, cc)))
            ys.append(yi)
        yc = jnp.stack(ys)
    else:
        hfin, yc = jax.lax.scan(step, h0, (xc, dtc, bc, cc))
    y = jnp.moveaxis(yc, 0, 1).reshape(b, l, h, p_)
    return y, hfin


def ssm_apply(
    p: Params,
    x: jnp.ndarray,  # (B, S, D)
    cfg: ModelConfig,
    *,
    cache: Optional[dict] = None,
    mode: str = "train",
    par=None,
):
    s = cfg.ssm
    d_in, nh, conv_dim = ssm_dims(cfg)
    b, l, _ = x.shape
    zxbcdt = jnp.einsum("btd,de->bte", x, p["in_proj"]["w"].astype(x.dtype))
    z, xbc, dt = _split_zxbcdt(zxbcdt, cfg)
    a_coef = -jnp.exp(p["A_log"]["a"])  # (H,)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"]["b"][None, None, :])

    new_cache = cache
    if mode == "decode":
        assert l == 1 and cache is not None
        conv_state = cache["conv"]  # (B, K-1, conv_dim)
        window = jnp.concatenate([conv_state, xbc], axis=1)  # (B, K, conv)
        new_conv = window[:, 1:, :]
        w = p["conv_w"]["w"].astype(jnp.float32)
        conv_out = (window.astype(jnp.float32) * w[None, :, :]).sum(axis=1)
        xbc_t = jax.nn.silu(conv_out + p["conv_b"]["b"][None, :]).astype(x.dtype)
        xt = xbc_t[:, :d_in].reshape(b, nh, s.head_dim)
        bt = xbc_t[:, d_in : d_in + s.d_state]
        ct = xbc_t[:, d_in + s.d_state :]
        hstate = cache["ssm"]  # (B, H, P, N) fp32
        dt1 = dt[:, 0, :]  # (B, H)
        dec = jnp.exp(dt1 * a_coef[None, :])  # (B, H)
        upd = jnp.einsum("bh,bn,bhp->bhpn", dt1, bt.astype(jnp.float32),
                         xt.astype(jnp.float32))
        hstate = hstate * dec[:, :, None, None] + upd
        y = jnp.einsum("bn,bhpn->bhp", ct.astype(jnp.float32), hstate)
        y = y + p["D"]["d"][None, :, None] * xt.astype(jnp.float32)
        y = y.reshape(b, 1, d_in).astype(x.dtype)
        new_cache = {"conv": new_conv, "ssm": hstate}
    else:
        xbc = _causal_conv(xbc, p["conv_w"]["w"], p["conv_b"]["b"])
        xs = xbc[..., :d_in].reshape(b, l, nh, s.head_dim)
        bmat = xbc[..., d_in : d_in + s.d_state]
        cmat = xbc[..., d_in + s.d_state :]
        if par is not None and par.tp_for(nh):
            xs = par.constrain(xs, par.dp_for(b), None, par.tp_axis, None)
        y, hfin = ssd_chunked(xs, dt, a_coef, bmat, cmat, s.chunk,
                              unroll=cfg.unroll_layers)
        y = y + p["D"]["d"][None, None, :, None].astype(y.dtype) * xs
        y = y.reshape(b, l, d_in)
        if mode == "prefill" and cache is not None:
            k = s.d_conv
            new_cache = {"conv": xbc_raw_tail(zxbcdt, cfg, k), "ssm": hfin}
    y = y * jax.nn.silu(z)
    y = rmsnorm_apply({"scale": p["norm"]["scale"]}, y, cfg.norm_eps)
    return jnp.einsum("bte,ed->btd", y, p["out_proj"]["w"].astype(x.dtype)), new_cache


def xbc_raw_tail(zxbcdt, cfg, k):
    """Last k-1 pre-conv xBC inputs (prefill -> decode handoff)."""
    _, xbc, _ = _split_zxbcdt(zxbcdt, cfg)
    return xbc[:, -(k - 1) :, :]


def ssm_cache_init(cfg: ModelConfig, batch: int, dtype) -> dict:
    s = cfg.ssm
    d_in, nh, conv_dim = ssm_dims(cfg)
    return {
        "conv": jnp.zeros((batch, s.d_conv - 1, conv_dim), dtype),
        "ssm": jnp.zeros((batch, nh, s.head_dim, s.d_state), jnp.float32),
    }
