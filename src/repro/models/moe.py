"""Mixture-of-Experts with LOMS routing — the paper's primary integration.

Router: top-k over expert logits computed with the *blockwise LOMS merge*
(``repro.topk(backend="schedule")`` — local rank-sorts then truncated
UP-k/DN-k List Offset merges). This is pure-jnp oblivious networking, so
GSPMD shards it freely; the Pallas realization of the same network lives
in repro.kernels.topk and is used in the serving sampler.

Dispatch (expert parallelism): tokens are sequence-sharded over the
'model' axis for the MoE block; each shard buckets its local tokens into
capacity-bounded per-expert buffers, one all_to_all moves buckets to the
expert-owning shards, expert FFNs run as dense batched einsums, and a
second all_to_all returns outputs — deterministic shapes end to end.

``dispatch='sorted'`` demonstrates the paper's oblivious-routing angle:
bucket positions come from an actual List-Offset sort network over the
(expert_id, token) pairs instead of the cumsum — bit-identical routing,
data-oblivious schedule (usable for the paper's safety/security argument).
Used for small token counts (tests/examples); 'scatter' (cumsum) is the
production path.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import sort as unified_sort
from repro.api import topk as unified_topk
from repro.configs.base import ModelConfig
from .layers import dense_init

Params = dict


def moe_init(key, cfg: ModelConfig):
    mo = cfg.moe
    d, e, f = cfg.d_model, mo.n_experts, mo.d_expert
    ks = jax.random.split(key, 6)
    p, s = {}, {}
    p["router"], s["router"] = dense_init(ks[0], d, e, ("embed", "expert"))
    std = 1.0 / np.sqrt(d)
    p["wi"] = {"w": jax.random.normal(ks[1], (e, d, f), jnp.float32) * std}
    p["wg"] = {"w": jax.random.normal(ks[2], (e, d, f), jnp.float32) * std}
    p["wo"] = {"w": jax.random.normal(ks[3], (e, f, d), jnp.float32) * (1.0 / np.sqrt(f))}
    s["wi"] = {"w": ("expert", "embed", "mlp")}
    s["wg"] = {"w": ("expert", "embed", "mlp")}
    s["wo"] = {"w": ("expert", "mlp", "embed")}
    if mo.n_shared_experts:
        fs = f * mo.n_shared_experts
        p["shared_wi"], s["shared_wi"] = dense_init(ks[4], d, fs, ("embed", "mlp"))
        p["shared_wg"], s["shared_wg"] = dense_init(ks[5], d, fs, ("embed", "mlp"))
        p["shared_wo"], s["shared_wo"] = dense_init(
            jax.random.fold_in(ks[4], 7), fs, d, ("mlp", "embed"))
    return p, s


def router_topk(logits: jnp.ndarray, k: int, block: int):
    """LOMS blockwise top-k + renormalized softmax gates.

    logits: (T, E) -> gates (T, k) float, expert ids (T, k) int32."""
    e = logits.shape[-1]
    blk = min(block, e)
    while e % blk:
        blk -= 1
    # backend pinned to the pure-jnp schedule executor: the router runs
    # inside shard_map/GSPMD traces where the oblivious network shards freely
    vals, idx = unified_topk(
        logits.astype(jnp.float32), k, block=blk, backend="schedule")
    gates = jax.nn.softmax(vals, axis=-1)
    return gates, idx


def _positions_cumsum(flat_e: jnp.ndarray, n_experts: int):
    """GShard position-in-expert via one-hot cumsum (production path)."""
    oh = (flat_e[:, None] == jnp.arange(n_experts)[None, :]).astype(jnp.int32)
    pos = jnp.cumsum(oh, axis=0) - 1
    return (pos * oh).sum(-1)


def _positions_sorted(flat_e: jnp.ndarray, n_experts: int, par=None):
    """Oblivious position-in-expert via a List Offset sort network.

    Sort composite keys (expert_id * n + arrival_index) — unique, so the
    (unstable) LOMS network yields a STABLE expert grouping, bit-identical
    to the cumsum path; position-in-expert = rank - start_of_expert.
    Data-oblivious end to end (the paper's security/safety use case).

    On TPU without a sharding offer, the key sort routes through the
    segmented backend's kernel path (``repro.segment_sort`` with
    ``backend="segmented"``) whenever the problem fits one size class:
    the bucketed class network is exactly as oblivious as the schedule
    executor (a fixed trace-time comparison network, no data-dependent
    control flow), so the security/safety property is preserved while the
    sort gains the fused single-launch kernel. Two guards keep the old
    executor path: problems past the class budget (the segmented spill
    path's argsort is *not* oblivious and must never be picked here) and
    non-TPU hosts (interpret-mode kernel emulation would only slow the
    already-oblivious executor down). ``REPRO_DISABLE_SEGMENTED``
    restores the executor path outright. With a TP-sharded ``par`` (the
    non-EP path, where this runs outside any shard_map) the planner may
    instead route to the distributed sample-sort — large token counts
    then sort device-parallel."""
    n = flat_e.shape[0]
    keys = flat_e.astype(jnp.int32) * n + jnp.arange(n, dtype=jnp.int32)
    from repro.segmented import max_class_width, segmented_enabled

    if (par is None and segmented_enabled()
            and jax.default_backend() == "tpu"
            and n <= max_class_width(jnp.int32)):
        from repro.api import segment_sort

        sorted_keys, perm = segment_sort(
            keys, (0, n), payload=jnp.arange(n, dtype=jnp.int32),
            backend="segmented")
    else:
        sorted_keys, perm = unified_sort(
            keys, payload=jnp.arange(n, dtype=jnp.int32),
            backend="schedule" if par is None else "auto", par=par)
    sorted_e = sorted_keys // n
    counts = (flat_e[:, None] == jnp.arange(n_experts)[None, :]).sum(0)
    starts = jnp.cumsum(counts) - counts
    pos_sorted = jnp.arange(n) - starts[sorted_e]
    # scatter back to original slot order
    pos = jnp.zeros((n,), jnp.int32).at[perm].set(pos_sorted.astype(jnp.int32))
    return pos


def _expert_ffn(buf, p, act: str = "swiglu"):
    """buf: (E_local, C, D); expert weights stacked on the leading axis."""
    h = jnp.einsum("ecd,edf->ecf", buf, p["wi"]["w"].astype(buf.dtype))
    g = jnp.einsum("ecd,edf->ecf", buf, p["wg"]["w"].astype(buf.dtype))
    h = jax.nn.silu(h) * g
    return jnp.einsum("ecf,efd->ecd", h, p["wo"]["w"].astype(buf.dtype))


def _expert_ffn_csr(buf, p, caps: np.ndarray, starts: np.ndarray):
    """Expert FFN over a CSR buffer with static ragged capacities.

    ``buf``: (sum(caps), D) — expert ``i`` owns rows
    ``starts[i]:starts[i]+caps[i]``. The size-class idea of
    repro.segmented applied to expert *compute*: experts with equal
    capacity share one batched einsum, so a few large-capacity experts no
    longer force every buffer (and every FLOP) up to the max. Uniform
    capacities collapse to a single class = the dense path's one einsum."""
    d = buf.shape[-1]
    out = jnp.zeros_like(buf)
    classes = {}
    for i, c in enumerate(np.asarray(caps).tolist()):
        classes.setdefault(int(c), []).append(i)
    for c, ids in sorted(classes.items()):
        if c == 0:
            continue
        gmap = np.asarray(starts)[ids][:, None] + np.arange(c)[None, :]
        sub = buf[jnp.asarray(gmap)]  # (n_ids, c, D)
        pc = {nm: {"w": p[nm]["w"][jnp.asarray(ids)]}
              for nm in ("wi", "wg", "wo")}
        res = _expert_ffn(sub, pc)
        out = out.at[jnp.asarray(gmap.reshape(-1))].set(res.reshape(-1, d))
    return out


def moe_ffn_local(
    p: Params,
    x: jnp.ndarray,  # (T, D) local tokens
    cfg: ModelConfig,
    *,
    axis_name: Optional[str] = None,
    ep_size: int = 1,
    ep_psum: bool = False,
    par=None,
):
    """Routed expert FFN on local tokens. Two expert-parallel modes:
    all_to_all (tokens sequence-sharded; training/prefill) and ep_psum
    (tokens replicated over the EP axis, each rank computes only its own
    experts' contribution, one psum combines — used at decode where a
    single token cannot be sequence-sharded)."""
    mo = cfg.moe
    t, d = x.shape
    e, k = mo.n_experts, mo.top_k
    logits = jnp.einsum("td,de->te", x, p["router"]["w"].astype(x.dtype))
    gates, eids = router_topk(logits, k, mo.router_block)

    if ep_psum and axis_name is not None and ep_size > 1:
        e_loc = e // ep_size
        rank = jax.lax.axis_index(axis_name)
        local = (eids // e_loc) == rank
        gates = gates * local  # zero out non-local expert choices
        eids = jnp.where(local, eids - rank * e_loc, 0)
        e = e_loc  # bucket over local experts only; weights already local

    flat_e = eids.reshape(-1)
    tok_of = jnp.arange(t * k, dtype=jnp.int32) // k
    cap = int(np.ceil(t * k / e * mo.capacity_factor))
    cap = max(4, cap + (-cap) % 4)
    # the oblivious sorted dispatch is affordable up to 4096 keys on one
    # device; with a TP axis the distributed sample-sort extends the range
    # (keys stay exact int32 composites: e * t * k < 2^31 holds there).
    # The raise only applies from DIST_MIN_TOTAL up — below it the planner
    # would still pick the expensive single-device merge-tree sort.
    sorted_cap = 4096
    if par is not None and t * k >= sorted_cap and e * t * k < 2 ** 31:
        from repro.parallel.dist_sort import DIST_MIN_TOTAL
        from repro.parallel.sharding import dist_sort_axis

        if (t * k >= DIST_MIN_TOTAL
                and dist_sort_axis(par, (t * k,)) is not None):
            sorted_cap = 1 << 16
    if mo.dispatch == "sorted" and t * k <= sorted_cap:
        pos = _positions_sorted(flat_e, e, par=par)
    else:
        pos = _positions_cumsum(flat_e, e)

    if mo.expert_capacities is not None:
        # CSR ragged dispatch: expert i owns exactly caps[i] slots instead
        # of every buffer padding to a uniform capacity; the FFN runs one
        # einsum per capacity class (_expert_ffn_csr). Static shapes
        # throughout — the raggedness lives in the trace-time offsets.
        assert axis_name is None or ep_size == 1, (
            "expert_capacities is a non-EP feature: EP buckets must "
            "travel as dense (E, C, D) through all_to_all")
        caps_np = np.asarray(mo.expert_capacities, np.int64)
        assert caps_np.shape == (e,), (caps_np.shape, e)
        starts_np = np.concatenate([[0], np.cumsum(caps_np)])
        total = int(starts_np[-1])
        caps_j = jnp.asarray(caps_np, jnp.int32)
        starts_j = jnp.asarray(starts_np[:-1], jnp.int32)
        keep = pos < caps_j[flat_e]
        dest = jnp.where(keep, starts_j[flat_e] + pos, total)  # spill row
        buf_flat = jnp.zeros((total + 1, d), x.dtype).at[dest].add(x[tok_of])
        flat_out = _expert_ffn_csr(buf_flat[:-1], p, caps_np, starts_np)
        flat_out = jnp.concatenate([flat_out, jnp.zeros((1, d), x.dtype)])
        y_choice = flat_out[dest]  # spill row reads the zero pad
    else:
        keep = pos < cap
        dest = jnp.where(keep, flat_e * cap + pos, e * cap)  # spill row
        buf = jnp.zeros((e * cap + 1, d), x.dtype).at[dest].add(x[tok_of])
        buf = buf[:-1].reshape(e, cap, d)

        if axis_name is not None and ep_size > 1 and not ep_psum:
            # (E, C, D) -> (E/P, P*C, D): buckets travel to expert owners
            buf = jax.lax.all_to_all(buf, axis_name, split_axis=0,
                                     concat_axis=1, tiled=True)
            out = _expert_ffn(buf, p)
            out = jax.lax.all_to_all(out, axis_name, split_axis=1,
                                     concat_axis=0, tiled=True)
        else:
            out = _expert_ffn(buf, p)

        flat_out = out.reshape(e * cap, d)
        y_choice = flat_out[jnp.minimum(dest, e * cap - 1)]
    w = (gates.reshape(-1) * keep).astype(x.dtype)
    y = (y_choice * w[:, None]).reshape(t, k, d).sum(axis=1)

    if ep_psum and axis_name is not None and ep_size > 1:
        y = jax.lax.psum(y, axis_name)

    if mo.n_shared_experts:
        h = jnp.einsum("td,df->tf", x, p["shared_wi"]["w"].astype(x.dtype))
        g = jnp.einsum("td,df->tf", x, p["shared_wg"]["w"].astype(x.dtype))
        y = y + jnp.einsum(
            "tf,fd->td", jax.nn.silu(h) * g, p["shared_wo"]["w"].astype(x.dtype))
    return y


def moe_apply(p: Params, x: jnp.ndarray, cfg: ModelConfig, par=None):
    """x: (B, S, D). With a parallel context, run expert-parallel under
    shard_map (tokens sequence-sharded over the TP axis for this block)."""
    b, s, d = x.shape
    if par is None or not par.ep_enabled:
        # par (when given) rides along so the oblivious sorted dispatch can
        # engage the distributed sample-sort; inside the shard_map EP path
        # below it must stay None (no nested meshes)
        y = moe_ffn_local(p, x.reshape(b * s, d), cfg, par=par)
        return y.reshape(b, s, d)

    from jax.sharding import PartitionSpec as P

    mesh = par.mesh
    dp, tp = par.dp_axes, par.tp_axis
    ep_size = mesh.shape[tp]

    seq_shardable = s % ep_size == 0 and s >= ep_size

    def body(xb, pb):
        bb, sb, _ = xb.shape
        y = moe_ffn_local(pb, xb.reshape(bb * sb, d), cfg,
                          axis_name=tp, ep_size=ep_size,
                          ep_psum=not seq_shardable)
        return y.reshape(bb, sb, d)

    pspecs = jax.tree.map(lambda _: P(), p)
    for name in ("wi", "wg", "wo"):
        pspecs[name] = {"w": P(tp)}  # experts sharded over the TP axis
    x_spec = P(dp, tp, None) if seq_shardable else P(dp, None, None)
    from repro.parallel.sharding import shard_map_compat

    return shard_map_compat(
        body, mesh,
        in_specs=(x_spec, pspecs),
        out_specs=x_spec,
    )(x, p)
