"""Model building blocks: functional, param-dict based, spec-annotated.

Every ``init_*`` returns ``(params, specs)`` where ``specs`` mirrors the
param tree with tuples of *logical axis names*; ``repro.parallel.sharding``
maps logical axes onto mesh axes (FSDP over 'data', TP over 'model').
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

Params = dict
Specs = dict


def _norm_init(shape, dtype=jnp.float32):
    return jnp.ones(shape, dtype)


def dense_init(key, in_dim, out_dims, spec, bias=False, scale=None):
    """W: (in_dim, *out_dims). spec: logical axes, len == 1 + len(out_dims)."""
    out_dims = tuple(out_dims) if isinstance(out_dims, (tuple, list)) else (out_dims,)
    fan_out = int(np.prod(out_dims))
    std = scale if scale is not None else 1.0 / math.sqrt(in_dim)
    w = jax.random.normal(key, (in_dim, *out_dims), jnp.float32) * std
    p, s = {"w": w}, {"w": tuple(spec)}
    if bias:
        p["b"] = jnp.zeros(out_dims, jnp.float32)
        s["b"] = tuple(spec[1:])
    del fan_out
    return p, s


def dense_apply(p, x, dims: str):
    """einsum x @ w with ``dims`` like 'btd,dhq->bthq'; adds bias if present."""
    w = p["w"].astype(x.dtype)
    y = jnp.einsum(dims, x, w)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def rmsnorm_init(dim):
    return {"scale": _norm_init((dim,))}, {"scale": ("embed",)}


def rmsnorm_apply(p, x, eps=1e-5):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(dt)


def head_rmsnorm_init(dim):
    return {"scale": _norm_init((dim,))}, {"scale": ("head_dim",)}


def embed_init(key, vocab, dim):
    w = jax.random.normal(key, (vocab, dim), jnp.float32) * 0.02
    return {"emb": w}, {"emb": ("vocab", "embed")}


def embed_apply(p, tokens, dtype):
    return jnp.take(p["emb"].astype(dtype), tokens, axis=0)


def unembed_apply(p_emb, p_head, x, tie: bool):
    if tie:
        return jnp.einsum("btd,vd->btv", x, p_emb["emb"].astype(x.dtype))
    return dense_apply(p_head, x, "btd,dv->btv")


# ---------------------------------------------------------------------------
# RoPE (standard + partial/2D fraction)
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, fraction: float, theta: float):
    rot = int(head_dim * fraction)
    rot -= rot % 2
    inv = 1.0 / (theta ** (np.arange(0, rot, 2, dtype=np.float32) / rot))
    return rot, jnp.asarray(inv)


def apply_rope(x, positions, fraction: float, theta: float):
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    rot, inv = rope_freqs(d, fraction, theta)
    if rot == 0:
        return x
    xr, xp = x[..., :rot], x[..., rot:]
    ang = positions[..., :, None].astype(jnp.float32) * inv  # (..., S, rot/2)
    cos = jnp.cos(ang)[..., None, :].astype(x.dtype)
    sin = jnp.sin(ang)[..., None, :].astype(x.dtype)
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    out = jnp.stack([o1, o2], axis=-1).reshape(xr.shape)
    return jnp.concatenate([out, xp], axis=-1) if rot < d else out


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def mlp_init(key, d_model, d_ff, act: str):
    ks = jax.random.split(key, 3)
    if act == "swiglu":
        pw, sw = dense_init(ks[0], d_model, d_ff, ("embed", "mlp"))
        pv, sv = dense_init(ks[1], d_model, d_ff, ("embed", "mlp"))
        po, so = dense_init(ks[2], d_ff, d_model, ("mlp", "embed"))
        return ({"wi": pw, "wg": pv, "wo": po}, {"wi": sw, "wg": sv, "wo": so})
    pw, sw = dense_init(ks[0], d_model, d_ff, ("embed", "mlp"))
    po, so = dense_init(ks[2], d_ff, d_model, ("mlp", "embed"))
    return ({"wi": pw, "wo": po}, {"wi": sw, "wo": so})


def mlp_apply(p, x, act: str):
    h = dense_apply(p["wi"], x, "btd,df->btf")
    if act == "swiglu":
        g = dense_apply(p["wg"], x, "btd,df->btf")
        h = jax.nn.silu(h) * g
    else:
        h = jax.nn.gelu(h)
    return dense_apply(p["wo"], h, "btf,fd->btd")
