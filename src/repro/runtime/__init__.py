from .train_loop import TrainConfig, make_train_step, train, train_with_retries  # noqa: F401
