"""Fault-tolerant training runtime: jitted step, watchdog, retry loop.

Failure model (designed for 1000+ nodes, exercised here on 1):
  * hard fault (host/device dies) -> process exits -> the launcher
    (launch/train.py --retries N) restarts, the run auto-resumes from the
    latest atomic checkpoint, and the data pipeline is a pure function of
    step so no samples repeat or skip;
  * elastic restart -> the new process may see a different device count;
    restore() re-sorts arrays onto the new mesh (full-array checkpoints);
  * straggler steps -> a deadline watchdog flags steps slower than
    ``straggler_factor`` x the running median; the hook logs (and on a real
    fleet would trigger hot-spare swap / re-slice — documented in
    DESIGN.md §6).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.obs import metrics as obs_metrics
from repro.obs.timing import time_once
from repro.obs.trace import span
from repro.configs.base import ModelConfig
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.models import loss_fn, model_init
from repro.optim.adamw import OptConfig, opt_init, opt_update


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    log_every: int = 10
    straggler_factor: float = 3.0
    remat: str = "none"
    donate: bool = True
    seed: int = 0


def make_train_step(cfg: ModelConfig, oc: OptConfig, par=None, remat="none"):
    def step_fn(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(p, batch, cfg, par=par, remat=remat))(params)
        params, opt_state, metrics = opt_update(grads, opt_state, params, oc)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return step_fn


class StragglerMonitor:
    def __init__(self, factor: float):
        self.factor = factor
        self.times = []
        self.flagged = 0

    def record(self, dt: float) -> bool:
        self.times.append(dt)
        if len(self.times) < 5:
            return False
        med = float(np.median(self.times[-50:]))
        if dt > self.factor * med:
            self.flagged += 1
            return True
        return False


def train(
    cfg: ModelConfig,
    dc: DataConfig,
    tc: TrainConfig,
    oc: OptConfig,
    par=None,
    fail_at_step: Optional[int] = None,  # fault-injection hook for tests
) -> dict:
    """Run (or resume) training; returns final metrics."""
    pipeline = TokenPipeline(cfg, dc)
    ckpt = CheckpointManager(tc.ckpt_dir)

    params, _ = model_init(jax.random.PRNGKey(tc.seed), cfg)
    opt_state = opt_init(params)
    start_step = 0
    latest = ckpt.latest_step()
    if latest is not None:
        (params, opt_state), extra = ckpt.restore(
            latest, (params, opt_state))
        start_step = extra["step"]
        print(f"[train] resumed from step {start_step}")

    step_fn = jax.jit(make_train_step(cfg, oc, par=par, remat=tc.remat),
                      donate_argnums=(0, 1) if tc.donate else ())
    mon = StragglerMonitor(tc.straggler_factor)
    losses = []
    for step in range(start_step, tc.steps):
        batch = {k: jnp.asarray(v) for k, v in pipeline.get_batch(step).items()}
        if fail_at_step is not None and step == fail_at_step:
            raise RuntimeError(f"injected fault at step {step}")
        # one synchronized measurement per step (shared obs timing helper
        # instead of the loop's former inline perf_counter copy): the dt
        # feeds the StragglerMonitor and, with REPRO_OBS on, a train.step
        # span + step-time histogram land in the export
        with span("train.step", kind="run", step=step):
            (params, opt_state, metrics), dt = time_once(
                step_fn, params, opt_state, batch)
        loss = float(metrics["loss"])
        obs_metrics.counter("train.steps").inc()
        obs_metrics.histogram("train.step_ms").observe(dt * 1e3)
        if mon.record(dt):
            obs_metrics.counter("train.stragglers").inc()
            print(f"[train] STRAGGLER step {step}: {dt:.3f}s "
                  f"(median {np.median(mon.times[-50:]):.3f}s)")
        losses.append(loss)
        if step % tc.log_every == 0:
            print(f"[train] step {step} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} {dt*1e3:.0f}ms")
        if (step + 1) % tc.ckpt_every == 0 or step + 1 == tc.steps:
            ckpt.save(step + 1, (params, opt_state))
    ckpt.wait()
    return {"losses": losses, "final_loss": losses[-1] if losses else None,
            "stragglers": mon.flagged, "params": params}


def train_with_retries(cfg, dc, tc, oc, retries: int = 2, **kw):
    """Launcher-level fault tolerance: restart-on-failure, resume from the
    latest checkpoint each time."""
    attempt = 0
    while True:
        try:
            return train(cfg, dc, tc, oc, **kw)
        except Exception as e:  # noqa: BLE001 — any fault triggers restart
            attempt += 1
            if attempt > retries:
                raise
            print(f"[train] attempt {attempt} failed ({e}); restarting from "
                  f"latest checkpoint")
            kw["fail_at_step"] = None  # injected fault only fires once
