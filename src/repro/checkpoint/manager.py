"""Async, atomic, reshardable checkpointing.

Layout: <dir>/step_<N>/ with one .npy per tree leaf (path-encoded
filenames) + manifest.json (tree structure, shapes, dtypes, step, mesh
shape at save time). Writes go to a tmp dir then os.rename — a crashed
save can never corrupt the latest checkpoint (atomic-swap).

Restore is *elastic*: leaves are saved as full logical arrays, so a
restarted job may use a different device count/mesh — arrays are
device_put with the NEW shardings. (At 1000+ nodes one would save
per-shard files via distributed ocp-style I/O; the manifest already
records shardings to support that layout — see DESIGN.md §6.)

Saving is async: the arrays are snapshotted to host, then a background
thread serializes while training continues. ``wait()`` joins in-flight
saves (called before exit and before the next save).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional, Tuple

import jax
import numpy as np


def _flatten_with_paths(tree, prefix=()):
    out = []
    if isinstance(tree, dict):
        for k in sorted(tree.keys()):
            out += _flatten_with_paths(tree[k], prefix + (str(k),))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out += _flatten_with_paths(v, prefix + (f"#{i}",))
    else:
        out.append((prefix, tree))
    return out


def _tree_set(tree, path, value):
    node = tree
    for p in path[:-1]:
        node = node[int(p[1:])] if p.startswith("#") else node[p]
    last = path[-1]
    if last.startswith("#"):
        node[int(last[1:])] = value
    else:
        node[last] = value


class CheckpointManager:
    def __init__(self, directory: str, keep_last: int = 3):
        self.dir = directory
        self.keep_last = keep_last
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # -- save ---------------------------------------------------------------

    def save(self, step: int, state: Any, extra: Optional[dict] = None,
             blocking: bool = False):
        self.wait()
        # snapshot to host memory synchronously (cheap vs serialization)
        leaves = _flatten_with_paths(state)
        host = [("/".join(p), np.asarray(jax.device_get(v))) for p, v in leaves]
        manifest = {
            "step": int(step),
            "extra": extra or {},
            "leaves": [
                {"path": name, "shape": list(a.shape), "dtype": str(a.dtype)}
                for name, a in host
            ],
            "n_devices_at_save": jax.device_count(),
        }

        def _write():
            final = os.path.join(self.dir, f"step_{step:08d}")
            tmp = final + ".tmp"
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            for name, arr in host:
                fn = name.replace("/", "__") + ".npy"
                np.save(os.path.join(tmp, fn), arr)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)  # atomic publish
            self._gc()

        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep_last]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"), ignore_errors=True)

    # -- restore ------------------------------------------------------------

    def all_steps(self):
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.endswith(".tmp"):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, template: Any, shardings=None) -> Tuple[Any, dict]:
        """Load into the structure of ``template``; device_put with
        ``shardings`` (a matching pytree) if given — this is where elastic
        re-sharding happens."""
        path = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        state = jax.tree.map(lambda x: x, template)  # shallow copy of containers

        leaves = _flatten_with_paths(template)
        shard_leaves = _flatten_with_paths(shardings) if shardings is not None else None
        for i, (p, tmpl) in enumerate(leaves):
            fn = "/".join(p).replace("/", "__") + ".npy"
            arr = np.load(os.path.join(path, fn))
            assert list(arr.shape) == list(tmpl.shape), (p, arr.shape, tmpl.shape)
            if shard_leaves is not None:
                arr = jax.device_put(arr, shard_leaves[i][1])
            else:
                arr = jax.device_put(arr.astype(tmpl.dtype))
            _tree_set(state, p, arr)
        return state, manifest["extra"] | {"step": manifest["step"]}
